//! End-to-end contracts for the observability layer (PR 10):
//!
//! * **Non-perturbation** — attaching the ISS profiler or enabling span
//!   tracing changes no architectural or measured state: runs are
//!   bit-identical with observability on and off.
//! * **100% attribution** — the profiler's per-basic-block partition (and,
//!   for single runs, the marker-derived phase partition) sums *exactly*
//!   to the run's total simulated cycles; under serving, the aggregate
//!   across every warm session equals the metrics sink's `sim_cycles`.
//! * **Valid export** — the Chrome-trace JSON a serving run emits parses
//!   back and passes structural verification (required fields, per-lane
//!   span nesting, matched async pairs), with span counts covering every
//!   completed inference.
//!
//! The trace sink and the profile collector are process-global, so the
//! tests that touch them serialize on one mutex.

use std::sync::{Arc, Mutex, MutexGuard};

use fused_dsc::cfu::PipelineVersion;
use fused_dsc::compile::compile;
use fused_dsc::coordinator::loadgen::{self, LoadMode, LoadgenConfig};
use fused_dsc::coordinator::{Backend, Engine, EngineMode, ServeConfig};
use fused_dsc::model::blocks::BlockConfig;
use fused_dsc::model::weights::make_model_params;
use fused_dsc::obs;
use fused_dsc::util::json::Json;

/// Serializes the tests that use the process-global sink / collector.
static GLOBALS: Mutex<()> = Mutex::new(());

fn lock_globals() -> MutexGuard<'static, ()> {
    GLOBALS.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_params() -> fused_dsc::model::weights::ModelParams {
    make_model_params(Some(vec![
        BlockConfig::new(8, 8, 8, 16, 8, 2, false),
        BlockConfig::new(4, 4, 8, 16, 8, 1, true),
    ]))
}

#[test]
fn profiled_iss_run_is_bit_identical_and_fully_attributed() {
    let params = tiny_params();
    let cm = compile(&params, PipelineVersion::V3).unwrap();
    let engine = Engine::new(params, Backend::Reference);
    let x = engine.synthetic_input("obs.profiled");

    let plain = cm.run_iss(&x).unwrap();
    let (run, profile) = cm.run_iss_profiled(&x, false).unwrap();
    assert_eq!(run, plain, "attaching the profiler perturbed the run");

    profile.check().expect("100% attribution");
    assert_eq!(profile.total.cycles, run.cycles);
    assert_eq!(profile.block_cycle_sum(), run.cycles);
    assert_eq!(profile.phase_cycle_sum(), run.cycles);
    // Marker-exact phase partition: per block a glue phase + the block
    // itself, plus the classifier head.
    assert_eq!(profile.phases.len(), 2 * cm.params().blocks.len() + 1);
    assert!(!profile.blocks.is_empty(), "no basic blocks attributed");
    assert!(profile.total.instret > 0);

    // The per-instruction oracle loop under the profiler: same contract.
    let (srun, sprofile) = cm.run_iss_profiled(&x, true).unwrap();
    assert_eq!(srun, plain, "profiled stepped run diverged");
    sprofile.check().expect("stepped attribution");
    assert_eq!(sprofile.total.cycles, run.cycles);
}

#[test]
fn serving_profile_attributes_every_simulated_cycle() {
    let _g = lock_globals();
    let params = tiny_params();
    let n_blocks = params.blocks.len();
    let engine = Arc::new(Engine::new(params, Backend::Reference));
    let requests = 10usize;

    // Request collection before the coordinator starts: each worker's warm
    // IssSession attaches a profiler at construction and flushes it into
    // the global collector when the shard tears down (inside shutdown).
    obs::profile::request();
    let serve = ServeConfig {
        engine: EngineMode::CompiledIss,
        workers: 2,
        ..ServeConfig::default()
    };
    let report = loadgen::run(
        Arc::clone(&engine),
        &LoadgenConfig {
            mode: LoadMode::Closed { clients: 3 },
            requests,
            serve,
            metrics_out: None,
        },
        |i| engine.synthetic_input(&format!("obs.serve.{i}")),
    );
    assert_eq!(report.metrics.completed, requests as u64);

    let prof = obs::profile::take_collected().expect("sessions flushed a profiler");
    let profile = obs::Profile::from_collected(&prof, n_blocks);
    profile.check().expect("aggregate attribution");
    // The strong cross-subsystem invariant: the profiler's aggregate over
    // every session equals the metrics sink's summed per-request cycles.
    assert_eq!(
        profile.total.cycles, report.metrics.sim_cycles,
        "serving profile does not attribute every simulated cycle"
    );
    assert!(profile.total.cycles > 0);
    // Collection is one-shot: the flag was cleared with the take.
    assert!(!obs::profile::requested());
    assert!(obs::profile::take_collected().is_none());
}

#[test]
fn trace_export_round_trips_and_covers_serving() {
    let _g = lock_globals();
    let params = tiny_params();
    let n_blocks = params.blocks.len();
    let engine = Arc::new(Engine::new(
        params,
        Backend::FusedHost(PipelineVersion::V3),
    ));
    let x = engine.synthetic_input("obs.trace");
    // Reference outputs computed before the sink exists.
    let want = engine.infer(&x).unwrap();

    let sink = obs::trace::install(obs::TraceSink::new(16, 8192));
    obs::trace::set_enabled(true);

    // Tracing must not perturb inference.
    let traced = engine.infer(&x).unwrap();
    assert_eq!(traced.logits, want.logits);
    assert_eq!(traced.sim_cycles, want.sim_cycles);

    let requests = 8usize;
    let report = loadgen::run(
        Arc::clone(&engine),
        &LoadgenConfig {
            mode: LoadMode::Closed { clients: 2 },
            requests,
            serve: ServeConfig { workers: 2, ..ServeConfig::default() },
            metrics_out: None,
        },
        |i| engine.synthetic_input(&format!("obs.trace.{i}")),
    );
    obs::trace::set_enabled(false);
    assert_eq!(report.metrics.completed, requests as u64);

    // Export → parse → structural verification, exactly the CLI's path.
    let doc = Json::parse(&sink.to_chrome_json().render()).expect("trace JSON parses back");
    let check = obs::trace::verify_chrome_trace(&doc).expect("structurally valid trace");
    assert_eq!(check.dropped, 0, "rings sized for this run should not drop");
    assert!(check.threads >= 2, "spans from client and worker threads");

    // Coverage floors: every completed inference leaves its span shadow.
    let completed = report.metrics.completed as usize;
    assert!(check.count("inference") >= completed);
    assert!(check.count("admission") >= completed);
    assert!(check.count("response") >= completed);
    assert!(check.count("queue_wait") >= completed);
    assert!(
        check.count("block") >= completed * n_blocks,
        "want >= {} per-block spans, got {}",
        completed * n_blocks,
        check.count("block")
    );
}
