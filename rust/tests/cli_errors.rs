//! Negative-path CLI contract: unknown choice values fail fast, exit
//! non-zero, and — the part a user actually needs — name the valid
//! choices in the error message.

use std::process::{Command, Output};

fn run_cli(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_fused-dsc"))
        .args(args)
        .output()
        .expect("spawn fused-dsc")
}

fn failing_stderr(args: &[&str]) -> String {
    let out = run_cli(args);
    assert!(
        !out.status.success(),
        "`fused-dsc {}` should exit non-zero",
        args.join(" ")
    );
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn unknown_report_lists_the_valid_reports() {
    let err = failing_stderr(&["report", "bogus"]);
    assert!(err.contains("unknown report 'bogus'"), "got: {err}");
    for choice in ["table1", "fig14", "tune", "compile", "profile", "all"] {
        assert!(err.contains(choice), "error should offer '{choice}': {err}");
    }
}

#[test]
fn unknown_engine_mode_lists_the_valid_modes() {
    let err = failing_stderr(&["serve", "loadgen", "--requests", "1", "--engine", "bogus"]);
    assert!(err.contains("unknown engine mode 'bogus'"), "got: {err}");
    assert!(err.contains("exec | compiled-iss"), "got: {err}");
}

#[test]
fn unknown_qos_class_lists_the_valid_classes_fast() {
    // Must fail on parse, *before* the per-class tuning pass runs.
    let err = failing_stderr(&["serve", "--qos", "bogus", "--requests", "1"]);
    assert!(err.contains("unknown QoS class 'bogus'"), "got: {err}");
    assert!(err.contains("latency|energy|balanced"), "got: {err}");
}

#[test]
fn unknown_backend_points_at_backend_list() {
    let err = failing_stderr(&["run", "--backend", "bogus"]);
    assert!(err.contains("unknown backend 'bogus'"), "got: {err}");
    assert!(err.contains("--backend list"), "got: {err}");
}

#[test]
fn profile_without_compiled_iss_engine_is_rejected() {
    let err = failing_stderr(&["serve", "loadgen", "--requests", "1", "--profile", "."]);
    assert!(err.contains("--profile needs --engine compiled-iss"), "got: {err}");
}
