//! Cross-module integration tests (no artifacts required; the PJRT paths
//! live in `golden_cross_check.rs`).

use fused_dsc::baseline::cfu_playground::run_block_cfu_playground;
use fused_dsc::baseline::run_block_v0;
use fused_dsc::cfu::{CfuUnit, PipelineVersion};
use fused_dsc::coordinator::{Backend, Coordinator, Engine, ServeConfig};
use fused_dsc::driver::{run_block_fused, run_block_fused_stepped};
use fused_dsc::model::blocks::{backbone, BlockConfig};
use fused_dsc::model::refimpl::{block_ref, model_ref};
use fused_dsc::model::weights::{gen_input, make_block_params, make_model_params};
use fused_dsc::tensor::TensorI8;
use std::sync::Arc;

fn block_input(cfg: &BlockConfig, zp: i32, salt: &str) -> TensorI8 {
    TensorI8::from_vec(
        &[cfg.h as usize, cfg.w as usize, cfg.cin as usize],
        gen_input(salt, (cfg.h * cfg.w * cfg.cin) as usize, zp),
    )
}

/// Every execution path computes the same bytes on a mid-size block.
#[test]
fn all_paths_agree_on_one_block() {
    let cfg = BlockConfig::new(12, 10, 8, 48, 8, 1, true);
    let bp = make_block_params(4, cfg, -5);
    let x = block_input(&cfg, bp.zp_in(), "int.block");
    let want = block_ref(&x, &bp);

    let v0 = run_block_v0(&bp, &x).unwrap();
    assert_eq!(v0.out.data, want.data, "v0 software kernels");

    let pg = run_block_cfu_playground(&bp, &x).unwrap();
    assert_eq!(pg.out.data, want.data, "cfu-playground comparator");

    for v in PipelineVersion::ALL {
        let iss = run_block_fused(&bp, &x, v).unwrap();
        assert_eq!(iss.out.data, want.data, "fused ISS {}", v.name());
        let mut unit = CfuUnit::new(v);
        let (host, _) = unit.run_block_host(&bp, &x);
        assert_eq!(host.data, want.data, "fused host {}", v.name());
    }
}

/// The full 16-block backbone runs through the functional CFU and matches
/// the pure reference at the logits level.
#[test]
fn full_backbone_fused_host_matches_reference() {
    let params = make_model_params(None);
    let c0 = params.blocks[0].cfg;
    let x = block_input(&c0, params.blocks[0].zp_in(), "int.bb");
    let want = model_ref(&x, &params);
    let eng = Engine::new(params, Backend::FusedHost(PipelineVersion::V3));
    let got = eng.infer(&x).unwrap();
    assert_eq!(got.logits, want);
}

/// Speedup ordering holds on a realistically-sized block: v0 > pg > v1 >
/// v2 >= v3 in cycles.
#[test]
fn cycle_ordering_v0_pg_v1_v2_v3() {
    let cfg = BlockConfig::new(16, 16, 8, 48, 8, 1, true);
    let bp = make_block_params(3, cfg, -3);
    let x = block_input(&cfg, bp.zp_in(), "int.ord");
    let c0 = run_block_v0(&bp, &x).unwrap().cycles;
    let cpg = run_block_cfu_playground(&bp, &x).unwrap().cycles;
    let c1 = run_block_fused(&bp, &x, PipelineVersion::V1).unwrap().cycles;
    let c2 = run_block_fused(&bp, &x, PipelineVersion::V2).unwrap().cycles;
    let c3 = run_block_fused(&bp, &x, PipelineVersion::V3).unwrap().cycles;
    assert!(c0 > cpg, "v0 {c0} <= pg {cpg}");
    assert!(cpg > c1, "pg {cpg} <= v1 {c1}");
    assert!(c1 > c2, "v1 {c1} <= v2 {c2}");
    assert!(c2 >= c3, "v2 {c2} < v3 {c3}");
    assert!(c0 / c3 > 20, "fused speedup too small: {}", c0 / c3);
}

/// The block-dispatch engine and the retained per-instruction oracle agree
/// bit-for-bit on the full CFU driver path (program + CFU stalls + caches),
/// not just on synthetic ALU streams: same output bytes, same cycle count,
/// same CFU op/stall totals, same hit/miss split on both caches.
#[test]
fn fused_driver_block_dispatch_matches_stepped_oracle() {
    for (cfg, salt) in [
        (BlockConfig::new(7, 5, 8, 16, 16, 2, false), "int.bd1"),
        (BlockConfig::new(10, 10, 8, 48, 8, 1, true), "int.bd2"),
    ] {
        let bp = make_block_params(6, cfg, -4);
        let x = block_input(&cfg, bp.zp_in(), salt);
        for v in PipelineVersion::ALL {
            let b = run_block_fused(&bp, &x, v).unwrap();
            let s = run_block_fused_stepped(&bp, &x, v).unwrap();
            assert_eq!(b.out.data, s.out.data, "{} output", v.name());
            assert_eq!(
                (b.cycles, b.instret, b.cfu_ops, b.cfu_stall_cycles),
                (s.cycles, s.instret, s.cfu_ops, s.cfu_stall_cycles),
                "{} counters",
                v.name()
            );
            assert_eq!(
                (b.icache_hits, b.icache_misses, b.dcache_hits, b.dcache_misses),
                (s.icache_hits, s.icache_misses, s.dcache_hits, s.dcache_misses),
                "{} cache counters",
                v.name()
            );
        }
    }
}

/// Pin the ISS cycle model at block granularity against a committed
/// snapshot (same record-or-compare convention as `sim_cycles_mini.txt`):
/// the block-dispatch engine is a host-speed change only and must never
/// move simulated cycles, instret, or watch traffic.
#[test]
fn sim_cycles_golden_iss_block_run() {
    let cfg = BlockConfig::new(10, 10, 8, 48, 8, 1, true);
    let bp = make_block_params(3, cfg, -3);
    let x = block_input(&cfg, bp.zp_in(), "int.gold");
    let v0 = run_block_v0(&bp, &x).unwrap();
    let fused = run_block_fused(&bp, &x, PipelineVersion::V3).unwrap();
    let mut lines = String::new();
    lines.push_str(&format!("v0 {} {}\n", v0.cycles, v0.instret));
    lines.push_str(&format!(
        "v0.f1_watch {} {} {} {}\n",
        v0.f1_watch.loads, v0.f1_watch.stores, v0.f1_watch.bytes, v0.f1_watch.cycles
    ));
    lines.push_str(&format!("fused_v3 {} {}\n", fused.cycles, fused.instret));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/sim_cycles_iss.txt");
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            lines,
            want,
            "ISS block cycle snapshot diverged — if the cycle model changed \
             on purpose, delete {} and re-run to re-bless",
            path.display()
        ),
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &lines).unwrap();
            println!(
                "RECORDED: ISS block cycle snapshot at {} — commit it to pin \
                 the cycle model.",
                path.display()
            );
        }
    }
}

/// The v0 baseline moves every F1/F2 byte through RAM; the fused driver's
/// memory traffic contains no intermediate-buffer accesses at all.
#[test]
fn fused_design_eliminates_intermediate_traffic() {
    let cfg = BlockConfig::new(10, 10, 8, 48, 8, 1, true);
    let bp = make_block_params(3, cfg, -3);
    let x = block_input(&cfg, bp.zp_in(), "int.tr");
    let v0 = run_block_v0(&bp, &x).unwrap();
    let f1_bytes = (cfg.h * cfg.w * cfg.m) as u64;
    assert!(v0.f1_watch.stores >= f1_bytes);
    assert!(v0.f1_watch.loads >= f1_bytes);
    // The fused driver program simply has no F1/F2 buffers in its address
    // space — BlockLayout reserves them, but the driver never touches them.
    let fused = run_block_fused(&bp, &x, PipelineVersion::V3).unwrap();
    assert_eq!(fused.out.data, v0.out.data);
    // Traffic ratio: fused moves input+weights+output once (~4KB more than
    // 2x the io), v0 moves >4x the intermediate map on top.
    assert!(v0.cycles > 10 * fused.cycles);
}

/// Coordinator under concurrent load: all requests served, bit-exact.
#[test]
fn coordinator_end_to_end_consistency() {
    let params = make_model_params(Some(vec![
        BlockConfig::new(8, 8, 8, 16, 8, 2, false),
        BlockConfig::new(4, 4, 8, 16, 8, 1, true),
    ]));
    let engine = Arc::new(Engine::new(params, Backend::FusedHost(PipelineVersion::V2)));
    let coord = Coordinator::start(Arc::clone(&engine), ServeConfig::default());
    let inputs: Vec<TensorI8> = (0..24)
        .map(|i| {
            block_input(
                &engine.params.blocks[0].cfg,
                engine.params.blocks[0].zp_in(),
                &format!("int.c{i}"),
            )
        })
        .collect();
    let wants: Vec<Vec<i32>> = inputs.iter().map(|x| engine.infer(x).unwrap().logits).collect();
    let tickets: Vec<_> = inputs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
    for (t, want) in tickets.into_iter().zip(wants) {
        let out = t.wait().into_output().unwrap();
        assert_eq!(out.logits, want);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.rejected, 0);
    assert!(snap.sim_cycles > 0);
    assert_eq!(snap.total_latency.count, 24);
}

/// The tuner's headline behavior on the default model (the ISSUE 5
/// acceptance criterion): the latency objective selects a genuinely
/// heterogeneous plan — the fused CFU on the stride-2 downsampling blocks
/// (where its 9-engine × 8-lane expansion array runs fully fed at full
/// input resolution), the host core on the rest — that beats every
/// uniform plan on modeled latency, executes through the coordinator via
/// `ServeConfig::plan`, and serves logits bit-identical to the uniform
/// reference plan.
#[test]
fn tuner_selects_a_heterogeneous_plan_on_the_backbone() {
    use fused_dsc::tune::{self, Objective};
    let params = make_model_params(None);
    let result = tune::tune(&params, &tune::DEFAULT_ALLOWLIST).unwrap();

    let latency = result.plan_for(Objective::Latency);
    assert!(
        !latency.is_uniform(),
        "latency plan should mix host and CFU placements: [{}]",
        latency.placement_summary()
    );
    assert!(latency.placement.iter().any(|b| matches!(b, Backend::FusedHost(_))));
    assert!(latency.placement.iter().any(|b| *b == Backend::Reference));
    for uniform in result.uniform_plans() {
        assert!(
            latency.latency_s <= uniform.latency_s,
            "tuned latency {} worse than {}",
            latency.latency_s,
            uniform.objective
        );
    }
    // The energy objective stays on the accelerator (the paper's v3 draws
    // the least power AND finishes fastest among the CFU versions).
    let energy = result.plan_for(Objective::Energy);
    assert!(
        energy.placement.iter().all(|b| matches!(b, Backend::FusedHost(_))),
        "energy plan should stay on the CFU: [{}]",
        energy.placement_summary()
    );
    assert!(energy.energy_j < latency.energy_j);
    assert!(latency.latency_s < energy.latency_s);

    // The heterogeneous plan serves through the coordinator, bit-exact
    // against the uniform reference plan.
    let engine = Arc::new(Engine::new(params.clone(), Backend::Reference));
    let x = block_input(&params.blocks[0].cfg, params.blocks[0].zp_in(), "int.tune");
    let want = engine.infer(&x).unwrap();
    let plan = latency.to_execution_plan(&params).unwrap();
    let coord = Coordinator::start(
        Arc::clone(&engine),
        ServeConfig { plan: Some(plan), ..Default::default() },
    );
    let got = coord.submit(x).unwrap().wait().into_output().unwrap();
    assert_eq!(got.logits, want.logits);
    assert!(got.sim_cycles > 0, "the CFU-placed blocks contribute cycles");
}

/// Backbone geometry invariants used throughout the system.
#[test]
fn backbone_is_well_formed() {
    let bb = backbone();
    assert_eq!(bb.len(), 16);
    for b in &bb {
        b.validate().unwrap();
        assert!(b.m >= b.cin, "inverted residual expands");
    }
}

/// Weight generation matches between the direct generator and the QMW
/// round-trip (serialize -> parse -> reconstruct).
#[test]
fn weights_roundtrip_through_qmw() {
    use fused_dsc::model::weights::{from_qmw, to_qmw_tensors};
    use fused_dsc::tensor::io::{parse_qmw, serialize_qmw};
    let p = make_model_params(None);
    let blob = serialize_qmw(&to_qmw_tensors(&p));
    let back = from_qmw(&parse_qmw(&blob).unwrap()).unwrap();
    for (a, b) in p.blocks.iter().zip(&back.blocks) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.ex_w, b.ex_w);
        assert_eq!(a.dw_w, b.dw_w);
        assert_eq!(a.pr_w, b.pr_w);
        assert_eq!(a.qp_words(), b.qp_words());
    }
}

/// CFU STATUS opcode reflects pipeline readiness.
#[test]
fn cfu_status_opcode_tracks_readiness() {
    use fused_dsc::cfu::unit::opcodes;
    use fused_dsc::cpu::CfuPort;
    let cfg = BlockConfig::new(4, 4, 8, 16, 8, 1, false);
    let bp = make_block_params(2, cfg, 0);
    let x = block_input(&cfg, bp.zp_in(), "int.status");
    let mut unit = CfuUnit::new(PipelineVersion::V1);
    // Warm the unit through a full host run, then reprogram and poll.
    let _ = unit.run_block_host(&bp, &x);
    assert_eq!(unit.execute(opcodes::STATUS, 0, 0, 0, 0).value, 0, "drained batch not ready");
}

/// Disassembly smoke: every instruction class renders.
#[test]
fn disassembly_renders_all_classes() {
    use fused_dsc::isa::codec::{decode, encode};
    use fused_dsc::isa::*;
    let instrs = [
        Instr::Alu { op: AluOp::Mul, rd: 1, rs1: 2, rs2: 3 },
        Instr::AluImm { op: AluImmOp::Srai, rd: 4, rs1: 5, imm: 7 },
        Instr::Load { op: LoadOp::Lbu, rd: 6, rs1: 7, imm: -4 },
        Instr::Store { op: StoreOp::Sh, rs1: 8, rs2: 9, imm: 16 },
        Instr::Branch { op: BranchOp::Bgeu, rs1: 1, rs2: 2, imm: -8 },
        Instr::Lui { rd: 3, imm: 0x12000 },
        Instr::Jal { rd: 0, imm: 2048 },
        Instr::Jalr { rd: 1, rs1: 1, imm: 0 },
        Instr::Cfu { funct7: 0x09, funct3: 0, rd: 10, rs1: 11, rs2: 12 },
        Instr::Ecall,
        Instr::Ebreak,
    ];
    for i in instrs {
        let text = format!("{i}");
        assert!(!text.is_empty());
        assert_eq!(decode(encode(i)).unwrap(), i);
    }
}

/// Memory-traffic model scales quadratically with spatial size and
/// linearly with expansion width (the Eq.1 structure).
#[test]
fn traffic_model_scaling_laws() {
    use fused_dsc::memtraffic::traffic_dram_bytes;
    let base = BlockConfig::new(10, 10, 8, 48, 8, 1, true);
    let double_hw = BlockConfig::new(20, 20, 8, 48, 8, 1, true);
    let double_m = BlockConfig::new(10, 10, 8, 96, 8, 1, true);
    assert_eq!(traffic_dram_bytes(&double_hw), 4 * traffic_dram_bytes(&base));
    assert_eq!(traffic_dram_bytes(&double_m), 2 * traffic_dram_bytes(&base));
}

/// Failure injection: a driver program with a corrupted CFG word (bad
/// channel alignment) must be rejected by the CFU, not silently computed.
#[test]
fn cfu_rejects_misaligned_configuration() {
    use fused_dsc::cfu::unit::opcodes;
    use fused_dsc::cfu::CFG;
    use fused_dsc::cpu::CfuPort;
    let result = std::panic::catch_unwind(|| {
        let mut unit = CfuUnit::new(PipelineVersion::V3);
        let words = [
            (CFG::H, 4u32), (CFG::W, 4), (CFG::CIN, 12 /* not a multiple of 8 */),
            (CFG::M, 16), (CFG::COUT, 8), (CFG::STRIDE, 1),
            (CFG::ZP_IN, 0), (CFG::ZP_F1, 0), (CFG::ZP_F2, 0), (CFG::ZP_OUT, 0),
            (CFG::EX_MULT, 1 << 30), (CFG::EX_SHIFT, 0),
            (CFG::DW_MULT, 1 << 30), (CFG::DW_SHIFT, 0),
            (CFG::PR_MULT, 1 << 30), (CFG::PR_SHIFT, 0),
            (CFG::RELU, 0),
        ];
        for (i, v) in words {
            unit.execute(opcodes::CFG, 0, i, v, 0);
        }
    });
    assert!(result.is_err(), "misaligned Cin must be rejected");
}
