//! Counting-allocator regression test for the tentpole guarantee of
//! EXPERIMENTS.md §Perf iteration 3: after warm-up, the steady-state fused
//! pixel loop (START a row, drain it with RD_OUT) performs **zero** heap
//! allocations — the host-code analogue of the paper's zero-buffer
//! dataflow, where intermediates live only in pipeline registers.
//!
//! A wrapping global allocator counts allocation events on the current
//! thread (thread-local so the libtest harness threads cannot pollute the
//! count).  The counter uses a `const`-initialized thread-local `Cell`,
//! which itself never allocates on first access.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use fused_dsc::cfu::{opcodes, CfuUnit, PipelineVersion, CFG};
use fused_dsc::coordinator::{Backend, Engine, EngineShard, InferenceOutput, Metrics};
use fused_dsc::cpu::CfuPort;
use fused_dsc::model::blocks::BlockConfig;
use fused_dsc::model::weights::make_model_params;
use fused_dsc::tensor::TensorI8;

thread_local! {
    static ALLOC_EVENTS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOC_EVENTS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOC_EVENTS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_events_now() -> u64 {
    ALLOC_EVENTS.with(|c| c.get())
}

/// Program a 4x4x8 -> M=8 -> Cout=8 layer (identity-ish quant), mirroring
/// the driver firmware's CFG + WR_* sequence.
fn configured_unit() -> CfuUnit {
    let mut u = CfuUnit::new(PipelineVersion::V3);
    let words: [(u32, u32); 17] = [
        (CFG::H, 4),
        (CFG::W, 4),
        (CFG::CIN, 8),
        (CFG::M, 8),
        (CFG::COUT, 8),
        (CFG::STRIDE, 1),
        (CFG::ZP_IN, 0),
        (CFG::ZP_F1, 0),
        (CFG::ZP_F2, 0),
        (CFG::ZP_OUT, 0),
        (CFG::EX_MULT, 1 << 30),
        (CFG::EX_SHIFT, 0),
        (CFG::DW_MULT, 1 << 30),
        (CFG::DW_SHIFT, 0),
        (CFG::PR_MULT, 1 << 30),
        (CFG::PR_SHIFT, 0),
        (CFG::RELU, 0),
    ];
    for (i, v) in words {
        u.execute(opcodes::CFG, 0, i, v, 0);
    }
    for a in 0..(4 * 4 * 8 / 4) {
        u.execute(opcodes::WR_IFMAP, 0, a, 0x0201_0102, 0);
    }
    for a in 0..(8 * 8 / 4) {
        u.execute(opcodes::WR_EXW, 0, a, 0x0101_0101, 0);
    }
    for a in 0..(72 / 4) {
        u.execute(opcodes::WR_DWW, 0, a, 0x0101_0101, 0);
    }
    for a in 0..(8 * 8 / 4) {
        u.execute(opcodes::WR_PRW, 0, a, 0x0101_0101, 0);
    }
    u
}

/// START one row of `w_out` pixels and drain it word by word, exactly like
/// the driver firmware's per-row loop.
fn run_row(u: &mut CfuUnit, first: u32, w_out: u32, words_per_px: u32, now: &mut u64) {
    u.execute(opcodes::START, 0, first, w_out, *now);
    for _ in 0..w_out {
        for w in 0..words_per_px {
            let r = u.execute(opcodes::RD_OUT, 0, w, 0, *now);
            *now += 1 + r.stall_cycles;
        }
    }
}

#[test]
fn steady_state_fused_pixel_loop_allocates_nothing() {
    let mut u = configured_unit();
    let (w_out, words_per_px) = (4u32, 2u32);
    let mut now = 0u64;

    // Warm-up: the first row may size the flat output buffer and the
    // handshake window to their steady-state capacities.
    run_row(&mut u, 0, w_out, words_per_px, &mut now);

    let before = alloc_events_now();
    for row in 1..4u32 {
        run_row(&mut u, row * w_out, w_out, words_per_px, &mut now);
    }
    let after = alloc_events_now();
    assert_eq!(
        after - before,
        0,
        "steady-state fused pixel loop performed {} heap allocations \
         (expected zero after warm-up — FusedScratch or the flat output \
         buffer regressed)",
        after - before
    );
}

#[test]
fn steady_state_whole_model_warm_shard_inference_allocates_nothing() {
    // The PR-4 tentpole guarantee: not just the per-pixel loop but *full
    // model* inference — input load, every block through its warm executor
    // and the ping-pong arena, classifier head, argmax — performs zero
    // heap allocations on the warm shard path.  The first request sizes
    // the arena, each block's CfuUnit buffers, and the output's logits
    // vector; every request after that reuses all of it.
    let params = make_model_params(Some(vec![
        BlockConfig::new(8, 8, 8, 16, 8, 2, false),
        BlockConfig::new(4, 4, 8, 16, 16, 1, false),
        BlockConfig::new(4, 4, 16, 32, 16, 1, true),
    ]));
    let engine = Arc::new(Engine::new(params, Backend::FusedHost(PipelineVersion::V3)));
    let mut shard = EngineShard::new(Arc::clone(&engine));
    // Inputs are generated before the counting window (payload construction
    // is the client's allocation, not the shard's).
    let inputs: Vec<TensorI8> =
        (0..5).map(|i| engine.synthetic_input(&format!("alloc.m{i}"))).collect();
    let mut out = InferenceOutput::default();

    // Warm-up request.
    shard.infer_into(&inputs[0], &mut out).unwrap();
    let warm_logits = out.logits.clone();

    let before = alloc_events_now();
    for x in &inputs[1..] {
        shard.infer_into(x, &mut out).unwrap();
    }
    let after = alloc_events_now();
    assert_eq!(
        after - before,
        0,
        "steady-state whole-model warm-shard inference performed {} heap \
         allocations (expected zero after warm-up — the ExecutionPlan / \
         ActivationArena / warm-executor path regressed)",
        after - before
    );
    // The inferences actually computed (distinct inputs, live outputs).
    assert!(!out.logits.is_empty());
    assert_ne!(out.logits, warm_logits, "distinct inputs should move the logits");
    let want = engine.infer(&inputs[4]).unwrap();
    assert_eq!(out.logits, want.logits, "warm path must stay bit-identical");
    assert_eq!(out.sim_cycles, want.sim_cycles);
}

#[test]
fn steady_state_multi_threaded_inference_allocates_nothing() {
    // The multi-threaded fused pixel loop must preserve the zero-allocation
    // steady state: the RowPool lane buffers (per-chunk FusedScratch and
    // staging outputs) are sized during materialize / the first batch, and
    // every batch after that reuses them.  The allocation counter is
    // thread-local, so this window observes the submitting thread — the
    // one that resizes the flat output buffer, runs chunk 0 of every
    // batch, and stitches the lane outputs back together.
    use fused_dsc::exec::ExecutionPlan;
    let params = make_model_params(Some(vec![
        BlockConfig::new(8, 8, 8, 16, 8, 2, false),
        BlockConfig::new(4, 4, 8, 16, 16, 1, false),
        BlockConfig::new(4, 4, 16, 32, 16, 1, true),
    ]));
    let backend = Backend::FusedHost(PipelineVersion::V3);
    let plan = ExecutionPlan::uniform(&params, backend).with_threads(3);
    let engine = Arc::new(Engine::with_plan(params.clone(), plan));
    let mut shard = EngineShard::new(Arc::clone(&engine));
    let inputs: Vec<TensorI8> =
        (0..5).map(|i| engine.synthetic_input(&format!("alloc.t{i}"))).collect();
    let mut out = InferenceOutput::default();

    // Warm-up request sizes the arena, the lane buffers, and the logits.
    shard.infer_into(&inputs[0], &mut out).unwrap();

    let before = alloc_events_now();
    for x in &inputs[1..] {
        shard.infer_into(x, &mut out).unwrap();
    }
    let after = alloc_events_now();
    assert_eq!(
        after - before,
        0,
        "steady-state multi-threaded warm-shard inference performed {} heap \
         allocations on the submitting thread (expected zero after warm-up — \
         the RowPool lane-staging path regressed)",
        after - before
    );
    // Parallelism must not move the numbers: bit-identical to the scalar plan.
    let scalar = Engine::with_plan(params, ExecutionPlan::uniform(&engine.params, backend));
    let want = scalar.infer(&inputs[4]).unwrap();
    assert_eq!(out.logits, want.logits, "threaded path must stay bit-identical");
    assert_eq!(out.sim_cycles, want.sim_cycles);
}

#[test]
fn metrics_recording_is_o_buckets_not_o_requests() {
    // The serving metrics sink must not grow with request count: recording
    // into the atomic counters and the fixed-bucket histograms performs
    // zero heap allocations, so sustained load (millions of requests)
    // keeps memory at the O(buckets) footprint allocated at construction.
    use std::time::Duration;
    let m = Metrics::default();
    // Warm-up: construction already allocated the bucket tables; one
    // record proves the path is touched before we start counting.
    m.note_submitted();
    m.note_completed(Duration::from_micros(3), Duration::from_micros(9), 42);

    let before = alloc_events_now();
    for i in 0..100_000u64 {
        m.note_submitted();
        m.note_batch((i % 8 + 1) as usize);
        m.note_completed(
            Duration::from_nanos(100 + i * 37 % 5_000_000),
            Duration::from_nanos(500 + i * 91 % 9_000_000),
            i,
        );
        if i % 16 == 0 {
            m.note_rejected();
            m.note_failed(Duration::from_nanos(50), Duration::from_nanos(60));
        }
    }
    let after = alloc_events_now();
    assert_eq!(
        after - before,
        0,
        "recording 100k requests allocated {} times — the metrics sink \
         regressed from O(buckets) back toward O(requests)",
        after - before
    );
    // The data actually landed (not optimized away).
    let snap = m.snapshot();
    assert_eq!(snap.submitted, 100_001);
    assert_eq!(snap.total_latency.count as u64, snap.completed + snap.failed);
}

#[test]
fn span_instrumentation_allocates_nothing_on_the_hot_path() {
    // The observability overhead contract (ARCHITECTURE.md §Observability):
    // with tracing disabled an instrumentation point costs one relaxed
    // atomic load; enabled, spans are written into the sink's preallocated
    // per-thread ring slots.  Neither side may allocate on the steady-state
    // serving path — the sink's fixed rings at install time are the only
    // allocation the tracing subsystem ever makes.
    //
    // Disabled and enabled are measured inside one test so ordering is
    // deterministic: the process-global sink, once installed, stays for the
    // life of the process.
    use fused_dsc::obs;
    let params = make_model_params(Some(vec![
        BlockConfig::new(8, 8, 8, 16, 8, 2, false),
        BlockConfig::new(4, 4, 8, 16, 16, 1, false),
        BlockConfig::new(4, 4, 16, 32, 16, 1, true),
    ]));
    let engine = Arc::new(Engine::new(params, Backend::FusedHost(PipelineVersion::V3)));
    let mut shard = EngineShard::new(Arc::clone(&engine));
    let inputs: Vec<TensorI8> =
        (0..7).map(|i| engine.synthetic_input(&format!("alloc.s{i}"))).collect();
    let mut out = InferenceOutput::default();
    shard.infer_into(&inputs[0], &mut out).unwrap();

    // Tracing disabled (no sink installed yet in this process): the
    // span-instrumented inference loop stays allocation-free.
    let before = alloc_events_now();
    for x in &inputs[1..3] {
        shard.infer_into(x, &mut out).unwrap();
    }
    assert_eq!(
        alloc_events_now() - before,
        0,
        "span instrumentation with tracing disabled allocated on the warm-shard path"
    );

    // Sink setup is the one permitted allocation site: fixed-capacity
    // rings, sized up front.
    let sink = obs::trace::install(obs::TraceSink::new(8, 512));
    // Warm-up under tracing: the first span claims this thread's ring.
    shard.infer_into(&inputs[3], &mut out).unwrap();
    let recorded = sink.len();
    assert!(recorded > 0, "enabled tracing should be recording spans");

    let before = alloc_events_now();
    for x in &inputs[4..] {
        shard.infer_into(x, &mut out).unwrap();
    }
    assert_eq!(
        alloc_events_now() - before,
        0,
        "span recording allocated on the hot path (rings are preallocated at install)"
    );
    assert!(sink.len() > recorded, "steady-state spans were still recorded");
    let want = engine.infer(&inputs[6]).unwrap();
    assert_eq!(out.logits, want.logits, "tracing must not perturb inference");
    assert_eq!(out.sim_cycles, want.sim_cycles);
    obs::trace::set_enabled(false);
}

#[test]
fn warm_up_then_reconfigure_allocates_then_settles() {
    // Sanity check that the counter actually observes allocations: a layer
    // reconfiguration (materialize) must allocate, and the steady state
    // after its first row must again be allocation-free.
    let mut u = configured_unit();
    let mut now = 0u64;
    run_row(&mut u, 0, 4, 2, &mut now);

    let before = alloc_events_now();
    let mut u2 = configured_unit(); // fresh unit: CFG triggers materialize
    let mid = alloc_events_now();
    assert!(mid > before, "materialize should allocate buffers");

    run_row(&mut u2, 0, 4, 2, &mut now); // warm-up
    let b2 = alloc_events_now();
    run_row(&mut u2, 4, 4, 2, &mut now);
    run_row(&mut u2, 8, 4, 2, &mut now);
    assert_eq!(alloc_events_now() - b2, 0, "second unit steady state must be allocation-free");
}
