//! The three-layer validation: Rust CFU simulator vs the PJRT-executed AOT
//! artifacts (JAX/Pallas golden model).  Requires `make artifacts` AND a
//! build with a working PJRT runtime (`--features pjrt` + an XLA plugin).
//!
//! These tests skip loudly-but-green when artifacts or the runtime are
//! absent so `cargo test` works on a fresh offline checkout.  NOTE: until
//! the PJRT C-API FFI layer is vendored, `Runtime::cpu()` fails in every
//! configuration (even with a plugin installed), so the PJRT-executing
//! tests below currently always skip; the artifact-only tests (QMW pinning)
//! run whenever `make artifacts` has produced `model.qmw`.

use fused_dsc::cfu::{CfuUnit, PipelineVersion};
use fused_dsc::coordinator::{infer_golden, Backend, Engine};
use fused_dsc::driver::run_block_fused;
use fused_dsc::model::blocks::EVALUATED;
use fused_dsc::model::weights::{from_qmw, gen_input, make_model_params, to_qmw_tensors};
use fused_dsc::runtime::Runtime;
use fused_dsc::tensor::io::{load_qmw, serialize_qmw};
use fused_dsc::tensor::TensorI8;

fn artifacts_ready() -> bool {
    let dir = fused_dsc::artifacts_dir();
    let ok = dir.join("model.qmw").exists() && dir.join("block_l3.hlo.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts not found in {} — run `make artifacts`", dir.display());
    }
    ok
}

/// PJRT runtime, or None with a loud skip message (feature off / no libxla).
fn runtime_ready() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

/// The python-written QMW artifact is byte-identical to the Rust generator
/// — the cross-language determinism pin.
#[test]
fn qmw_artifact_matches_rust_generator() {
    if !artifacts_ready() {
        return;
    }
    let disk = std::fs::read(fused_dsc::artifacts_dir().join("model.qmw")).unwrap();
    let ours = serialize_qmw(&to_qmw_tensors(&make_model_params(None)));
    assert_eq!(disk.len(), ours.len());
    assert!(disk == ours, "QMW byte streams differ between python and rust generators");
}

/// Model parameters reconstructed from the artifact equal the generator's.
#[test]
fn qmw_artifact_parses_to_model_params() {
    if !artifacts_ready() {
        return;
    }
    let qmw = load_qmw(&fused_dsc::artifacts_dir().join("model.qmw")).unwrap();
    let parsed = from_qmw(&qmw).unwrap();
    let generated = make_model_params(None);
    assert_eq!(parsed.blocks.len(), generated.blocks.len());
    for (a, b) in parsed.blocks.iter().zip(&generated.blocks) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.qp_words(), b.qp_words());
    }
    assert_eq!(parsed.head.zp_in, generated.head.zp_in);
}

/// Every evaluated layer: CFU functional model AND the ISS driver path are
/// bit-exact against the PJRT-executed fused-Pallas HLO.
#[test]
fn evaluated_layers_bit_exact_vs_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let Some(rt) = runtime_ready() else {
        return;
    };
    let params = make_model_params(None);
    for (block_num, tag) in EVALUATED {
        let bp = &params.blocks[block_num - 1];
        let cfg = bp.cfg;
        let n = (cfg.h * cfg.w * cfg.cin) as usize;
        let path = fused_dsc::artifacts_dir().join(format!("block_l{block_num}.hlo.txt"));
        let exe = rt.load_hlo(&path, n).unwrap();
        let x = TensorI8::from_vec(
            &[cfg.h as usize, cfg.w as usize, cfg.cin as usize],
            gen_input(&format!("gx.{tag}"), n, bp.zp_in()),
        );
        let golden = exe
            .run_i8(&x.data, &[cfg.h as i64, cfg.w as i64, cfg.cin as i64])
            .unwrap();
        // Functional CFU model.
        let mut unit = CfuUnit::new(PipelineVersion::V3);
        let (host, _) = unit.run_block_host(bp, &x);
        assert_eq!(host.data, golden, "{tag}: host CFU vs golden");
        // Full ISS + RV32IM driver firmware path.
        let iss = run_block_fused(bp, &x, PipelineVersion::V3).unwrap();
        assert_eq!(iss.out.data, golden, "{tag}: ISS driver vs golden");
    }
}

/// The fused and layer-by-layer HLO artifacts agree with each other (the
/// in-graph ablation pair).
#[test]
fn fused_and_layerwise_artifacts_agree() {
    if !artifacts_ready() {
        return;
    }
    let Some(rt) = runtime_ready() else {
        return;
    };
    let params = make_model_params(None);
    for (block_num, tag) in EVALUATED {
        let bp = &params.blocks[block_num - 1];
        let cfg = bp.cfg;
        let n = (cfg.h * cfg.w * cfg.cin) as usize;
        let dir = fused_dsc::artifacts_dir();
        let fused = rt.load_hlo(&dir.join(format!("block_l{block_num}.hlo.txt")), n).unwrap();
        let lw = rt
            .load_hlo(&dir.join(format!("block_l{block_num}_layerwise.hlo.txt")), n)
            .unwrap();
        let x = TensorI8::from_vec(
            &[cfg.h as usize, cfg.w as usize, cfg.cin as usize],
            gen_input(&format!("glw.{tag}"), n, bp.zp_in()),
        );
        let dims = [cfg.h as i64, cfg.w as i64, cfg.cin as i64];
        assert_eq!(
            fused.run_i8(&x.data, &dims).unwrap(),
            lw.run_i8(&x.data, &dims).unwrap(),
            "{tag}: fused vs layerwise HLO"
        );
    }
}

/// Whole-backbone logits: simulator chain vs the single fused backbone HLO.
#[test]
fn backbone_logits_bit_exact_vs_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let dir = fused_dsc::artifacts_dir();
    if !dir.join("backbone.hlo.txt").exists() {
        eprintln!("SKIP: backbone.hlo.txt missing (aot --skip-backbone?)");
        return;
    }
    let Some(rt) = runtime_ready() else {
        return;
    };
    let params = make_model_params(None);
    let c0 = params.blocks[0].cfg;
    let n = (c0.h * c0.w * c0.cin) as usize;
    let x = TensorI8::from_vec(
        &[c0.h as usize, c0.w as usize, c0.cin as usize],
        gen_input("gbb.x", n, params.blocks[0].zp_in()),
    );
    let exe = rt.load_hlo(&dir.join("backbone.hlo.txt"), n).unwrap();
    let golden = infer_golden(&exe, &x).unwrap();
    let sim = Engine::new(params, Backend::FusedHost(PipelineVersion::V3)).infer(&x).unwrap();
    assert_eq!(sim.logits, golden.logits);
    assert_eq!(sim.class, golden.class);
}
