//! Design-space explorer: sweep the pipeline versions and timing parameters
//! across block shapes to see *where* inter- and intra-stage pipelining pay
//! off — the ablation behind the paper's §III-C design evolution.
//!
//! Run: `cargo run --release --example pipeline_explorer`

use fused_dsc::cfu::{PipelineVersion, StageTimes, TimingParams};
use fused_dsc::driver::run_block_fused;
use fused_dsc::model::blocks::BlockConfig;
use fused_dsc::model::weights::{gen_input, make_block_params};
use fused_dsc::tensor::TensorI8;
use fused_dsc::util::stats::fmt_cycles;

fn main() -> anyhow::Result<()> {
    println!("== analytical: initiation interval per version (cycles/pixel) ==");
    println!(
        "{:<26} {:>8} {:>8} {:>8}  ratio v1/v3",
        "shape (Cin->M->Cout)", "II v1", "II v2", "II v3"
    );
    let p = TimingParams::default();
    let shapes = [(8u32, 48u32, 8u32), (16, 96, 16), (24, 144, 24), (56, 336, 56), (8, 48, 64)];
    for (cin, m, cout) in shapes {
        let cfg = fused_dsc::cfu::LayerConfig {
            h: 16, w: 16, cin, m, cout, stride: 1, ..Default::default()
        };
        let t = StageTimes::for_layer(&cfg);
        let (i1, i2, i3) = (
            t.ii(PipelineVersion::V1, &p),
            t.ii(PipelineVersion::V2, &p),
            t.ii(PipelineVersion::V3, &p),
        );
        println!(
            "{:<26} {:>8} {:>8} {:>8}  {:.2}x",
            format!("{cin}->{m}->{cout}"),
            i1,
            i2,
            i3,
            i1 as f64 / i3 as f64
        );
    }

    println!("\n== measured on the ISS (driver overhead included) ==");
    println!(
        "{:<30} {:>10} {:>10} {:>10}  v1/v3",
        "block", "v1", "v2", "v3"
    );
    let blocks = [
        BlockConfig::new(20, 20, 8, 48, 8, 1, true),
        BlockConfig::new(20, 20, 16, 96, 16, 1, true),
        BlockConfig::new(10, 10, 24, 144, 24, 1, true),
        BlockConfig::new(10, 10, 8, 48, 16, 2, false),
    ];
    for cfg in blocks {
        let bp = make_block_params(7, cfg, -3);
        let x = TensorI8::from_vec(
            &[cfg.h as usize, cfg.w as usize, cfg.cin as usize],
            gen_input("explorer.x", (cfg.h * cfg.w * cfg.cin) as usize, bp.zp_in()),
        );
        let mut cycles = [0u64; 3];
        for (i, v) in PipelineVersion::ALL.iter().enumerate() {
            cycles[i] = run_block_fused(&bp, &x, *v)?.cycles;
        }
        println!(
            "{:<30} {:>10} {:>10} {:>10}  {:.2}x",
            format!(
                "{}x{}x{}->M{}->{} s{}{}",
                cfg.h, cfg.w, cfg.cin, cfg.m, cfg.cout, cfg.stride,
                if cfg.residual { " +res" } else { "" }
            ),
            fmt_cycles(cycles[0]),
            fmt_cycles(cycles[1]),
            fmt_cycles(cycles[2]),
            cycles[0] as f64 / cycles[2] as f64
        );
    }

    println!("\n== sensitivity: stage overhead vs pipelining gain (layer-3 shape) ==");
    let cfg = fused_dsc::cfu::LayerConfig {
        h: 40,
        w: 40,
        cin: 8,
        m: 48,
        cout: 8,
        stride: 1,
        ..Default::default()
    };
    let t = StageTimes::for_layer(&cfg);
    println!("{:>14} {:>8} {:>8} {:>8}", "stage_overhead", "II v1", "II v2", "II v3");
    for ovh in [0u64, 4, 16, 64, 256] {
        let p = TimingParams { start_overhead: 8, stage_overhead: ovh };
        println!(
            "{:>14} {:>8} {:>8} {:>8}",
            ovh,
            t.ii(PipelineVersion::V1, &p),
            t.ii(PipelineVersion::V2, &p),
            t.ii(PipelineVersion::V3, &p)
        );
    }
    println!("\n(With large per-stage overheads the versions converge — pipelining only pays");
    println!(" when stage boundaries are cheap, which is the v3 design point the paper picks.)");
    Ok(())
}
