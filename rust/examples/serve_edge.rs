//! Edge-serving scenario: a camera-like stream of inference requests goes
//! through the bounded, sharded coordinator backed by the fused accelerator
//! model.  Reports latency percentiles (from the bounded histogram),
//! throughput, shed/failed counts, and the simulated hardware time per
//! request — the deployment shape the paper's intro motivates (always-on
//! TinyML vision at the edge).
//!
//! Run: `cargo run --release --example serve_edge`

use std::sync::Arc;
use std::time::Duration;

use fused_dsc::cfu::PipelineVersion;
use fused_dsc::coordinator::{Backend, Coordinator, Engine, Rejected, ServeConfig};
use fused_dsc::model::weights::make_model_params;
use fused_dsc::tensor::TensorI8;
use fused_dsc::util::stats::fmt_cycles;

fn main() -> anyhow::Result<()> {
    let params = make_model_params(None);
    let engine = Arc::new(Engine::new(params, Backend::FusedHost(PipelineVersion::V3)));
    let cfg = ServeConfig {
        max_batch: 8,
        batch_timeout: Duration::from_millis(2),
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        // Small on purpose: a camera that falls behind should drop frames
        // (shed) rather than serve stale ones seconds late.
        queue_depth: 32,
        plan: None,
        threads: 1,
    };
    println!(
        "coordinator: max_batch={} workers={} queue_depth={} backend={}",
        cfg.max_batch,
        cfg.workers,
        cfg.queue_depth,
        engine.backend.name()
    );
    let coord = Coordinator::start(Arc::clone(&engine), cfg);

    // 256 frames arriving in bursts (camera frames + sporadic events).
    let n = 256;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n);
    let mut dropped_frames = 0u64;
    for i in 0..n {
        match coord.submit(frame(&engine, i as u64)) {
            Ok(t) => tickets.push(t),
            Err(Rejected::QueueFull { .. }) => dropped_frames += 1, // shed, move on
            Err(e) => anyhow::bail!("camera feed refused: {e}"),
        }
        if i % 16 == 15 {
            std::thread::sleep(Duration::from_millis(1)); // burst boundary
        }
    }
    let mut class_histogram = vec![0usize; 16];
    let mut failed = 0u64;
    for t in tickets {
        match t.wait().result {
            Ok(out) => class_histogram[out.class] += 1,
            Err(_) => failed += 1,
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics.snapshot();
    println!(
        "\nserved {} requests in {:.2}s -> {:.1} req/s (host wall-clock); shed {} failed {}",
        snap.completed,
        wall.as_secs_f64(),
        snap.completed as f64 / wall.as_secs_f64(),
        dropped_frames,
        failed
    );
    let (q, tot) = (&snap.queue_latency, &snap.total_latency);
    println!(
        "latency  p50/p90/p99/p999: {:.1}/{:.1}/{:.1}/{:.1} ms (queue p90 {:.1} ms)",
        tot.p50_s * 1e3,
        tot.p90_s * 1e3,
        tot.p99_s * 1e3,
        tot.p999_s * 1e3,
        q.p90_s * 1e3
    );
    println!(
        "batches: {} (max batch seen {}); simulated accelerator: {} cycles total, {:.2} ms @100MHz per request",
        snap.batches,
        snap.max_batch_seen,
        fmt_cycles(snap.sim_cycles),
        snap.sim_cycles as f64 / snap.completed.max(1) as f64 / 100e6 * 1e3
    );
    println!("class histogram: {class_histogram:?}");
    coord.shutdown();
    Ok(())
}

fn frame(engine: &Engine, salt: u64) -> TensorI8 {
    engine.synthetic_input(&format!("serve_edge.{salt}"))
}
