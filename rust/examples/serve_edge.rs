//! Edge-serving scenario: a camera-like stream of inference requests goes
//! through the batching coordinator backed by the fused accelerator model.
//! Reports latency percentiles, throughput, and the simulated hardware
//! time per request — the deployment shape the paper's intro motivates
//! (always-on TinyML vision at the edge).
//!
//! Run: `cargo run --release --example serve_edge`

use std::sync::Arc;
use std::time::Duration;

use fused_dsc::cfu::PipelineVersion;
use fused_dsc::coordinator::{Backend, Coordinator, Engine, ServeConfig};
use fused_dsc::model::weights::{gen_input, make_model_params};
use fused_dsc::tensor::TensorI8;
use fused_dsc::util::stats::fmt_cycles;

fn main() -> anyhow::Result<()> {
    let params = make_model_params(None);
    let engine = Arc::new(Engine::new(params, Backend::FusedHost(PipelineVersion::V3)));
    let cfg = ServeConfig {
        max_batch: 8,
        batch_timeout: Duration::from_millis(2),
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    println!(
        "coordinator: max_batch={} workers={} backend={}",
        cfg.max_batch,
        cfg.workers,
        engine.backend.name()
    );
    let coord = Coordinator::start(Arc::clone(&engine), cfg);

    // 256 requests arriving in bursts (camera frames + sporadic events).
    let n = 256;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        tickets.push(coord.submit(frame(&engine, i as u64)));
        if i % 16 == 15 {
            std::thread::sleep(Duration::from_millis(1)); // burst boundary
        }
    }
    let mut class_histogram = vec![0usize; 16];
    for t in tickets {
        let r = t.wait()?;
        class_histogram[r.class] += 1;
    }
    let wall = t0.elapsed();
    let snap = coord.metrics.snapshot();
    println!(
        "\nserved {} requests in {:.2}s -> {:.1} req/s (host wall-clock)",
        snap.completed,
        wall.as_secs_f64(),
        snap.completed as f64 / wall.as_secs_f64()
    );
    if let (Some(q), Some(tot)) = (snap.queue_latency, snap.total_latency) {
        println!(
            "latency  p50/p95/p99: {:.1}/{:.1}/{:.1} ms (queue p95 {:.1} ms)",
            tot.p50 * 1e3,
            tot.p95 * 1e3,
            tot.p99 * 1e3,
            q.p95 * 1e3
        );
    }
    println!(
        "batches: {} (max batch seen {}); simulated accelerator: {} cycles total, {:.2} ms @100MHz per request",
        snap.batches,
        snap.max_batch_seen,
        fmt_cycles(snap.sim_cycles),
        snap.sim_cycles as f64 / snap.completed as f64 / 100e6 * 1e3
    );
    println!("class histogram: {class_histogram:?}");
    coord.shutdown();
    Ok(())
}

fn frame(engine: &Engine, salt: u64) -> TensorI8 {
    let c = engine.params.blocks[0].cfg;
    TensorI8::from_vec(
        &[c.h as usize, c.w as usize, c.cin as usize],
        gen_input(&format!("serve_edge.{salt}"), (c.h * c.w * c.cin) as usize, engine.params.blocks[0].zp_in()),
    )
}
