//! Quickstart: run one inverted-residual block three ways and check they
//! agree bit-exactly —
//!
//!   1. the layer-by-layer Rust reference (the conventional model),
//!   2. the fused CFU simulator (the paper's zero-buffer dataflow),
//!   3. the PJRT-executed HLO artifact (the JAX/Pallas golden model),
//!
//! then print the measured speedup of the fused design over the software
//! baseline on the simulated RISC-V core.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use fused_dsc::baseline::run_block_v0;
use fused_dsc::cfu::{CfuUnit, PipelineVersion};
use fused_dsc::driver::run_block_fused;
use fused_dsc::model::refimpl::block_ref;
use fused_dsc::model::weights::{gen_input, make_model_params};
use fused_dsc::runtime::{artifact_path, Runtime};
use fused_dsc::tensor::TensorI8;
use fused_dsc::util::stats::fmt_cycles;

fn main() -> anyhow::Result<()> {
    // The paper's "3rd layer": 40x40x8, expanded to 48 channels, residual.
    let params = make_model_params(None);
    let bp = &params.blocks[2];
    let cfg = bp.cfg;
    println!(
        "block: {}x{}x{} -> M={} -> {} (stride {}, residual {})",
        cfg.h, cfg.w, cfg.cin, cfg.m, cfg.cout, cfg.stride, cfg.residual
    );

    let n = (cfg.h * cfg.w * cfg.cin) as usize;
    let x = TensorI8::from_vec(
        &[cfg.h as usize, cfg.w as usize, cfg.cin as usize],
        gen_input("quickstart.x", n, bp.zp_in()),
    );

    // 1. Conventional layer-by-layer reference (materializes F1, F2).
    let reference = block_ref(&x, bp);

    // 2. Fused pixel-wise CFU (no intermediate feature maps anywhere).
    let mut unit = CfuUnit::new(PipelineVersion::V3);
    let (fused, _) = unit.run_block_host(bp, &x);
    assert_eq!(fused.data, reference.data);
    println!("fused CFU        == layer-by-layer reference  ✓ (bit-exact)");

    // 3. PJRT golden model (the AOT-compiled JAX/Pallas kernel) — skipped
    // when the runtime or the artifacts are unavailable (offline checkout).
    match Runtime::cpu() {
        Ok(rt) => {
            let exe = rt.load_hlo(&artifact_path("block_l3.hlo.txt")?, n)?;
            let golden = exe.run_i8(&x.data, &[cfg.h as i64, cfg.w as i64, cfg.cin as i64])?;
            assert_eq!(golden, reference.data);
            println!("PJRT golden HLO  == layer-by-layer reference  ✓ (bit-exact)");
        }
        Err(e) => println!("PJRT golden HLO  skipped: {e}"),
    }

    // Cycle-accurate speedup on the simulated VexRiscv core.
    println!("\nmeasuring on the cycle-accurate RV32IM core (this runs ~60M simulated cycles)...");
    let v0 = run_block_v0(bp, &x)?;
    let v3 = run_block_fused(bp, &x, PipelineVersion::V3)?;
    assert_eq!(v0.out.data, v3.out.data);
    println!(
        "software baseline: {} cycles   fused v3: {} cycles   speedup: {:.1}x (paper: 59.3x)",
        fmt_cycles(v0.cycles),
        fmt_cycles(v3.cycles),
        v0.cycles as f64 / v3.cycles as f64
    );
    Ok(())
}
