//! End-to-end driver: full MobileNetV2-style backbone inference through the
//! whole system, proving all layers compose (DESIGN.md §2):
//!
//!   * 16 inverted-residual blocks + classifier head,
//!   * every block executed by the fused CFU driven by RV32IM firmware on
//!     the cycle-accurate core (the paper's measurement methodology),
//!   * logits cross-checked bit-exactly against the PJRT-executed
//!     `backbone.hlo.txt` (the AOT JAX/Pallas golden model),
//!   * per-layer cycle table + headline end-to-end speedup vs the software
//!     baseline.
//!
//! Recorded in EXPERIMENTS.md §E2E.
//! Run: `make artifacts && cargo run --release --example mobilenet_e2e`

use fused_dsc::cfu::PipelineVersion;
use fused_dsc::coordinator::{infer_golden, Backend, Engine};
use fused_dsc::model::blocks::NUM_CLASSES;
use fused_dsc::model::weights::{gen_input, make_model_params};
use fused_dsc::runtime::{artifact_path, Runtime};
use fused_dsc::tensor::TensorI8;
use fused_dsc::util::stats::fmt_cycles;

fn main() -> anyhow::Result<()> {
    let params = make_model_params(None);
    let c0 = params.blocks[0].cfg;
    let x = TensorI8::from_vec(
        &[c0.h as usize, c0.w as usize, c0.cin as usize],
        gen_input("e2e.x", (c0.h * c0.w * c0.cin) as usize, params.blocks[0].zp_in()),
    );
    println!(
        "input: {}x{}x{} synthetic int8 image features; {} blocks + head -> {} classes\n",
        c0.h, c0.w, c0.cin, params.blocks.len(), NUM_CLASSES
    );

    // --- Fused v3 on the ISS, per-layer cycles. ---
    let engine = Engine::new(params.clone(), Backend::FusedIss(PipelineVersion::V3));
    let mut a = x.clone();
    let mut total_v3 = 0u64;
    println!("{:<5} {:<16} {:>12} {:>10}", "blk", "shape", "v3 cycles", "ms@100MHz");
    let mut per_block = Vec::new();
    for i in 0..engine.params.blocks.len() {
        let cfg = engine.params.blocks[i].cfg;
        let (out, cycles) = engine.run_block(i, &a)?;
        println!(
            "{:<5} {:<16} {:>12} {:>10.3}",
            i + 1,
            format!("{}x{}x{}->{}", cfg.h, cfg.w, cfg.cin, cfg.cout),
            fmt_cycles(cycles),
            cycles as f64 / 100e6 * 1e3
        );
        per_block.push(cycles);
        total_v3 += cycles;
        a = out;
    }
    let out_v3 = engine.infer(&x)?;
    println!(
        "\nfused v3 total: {} cycles = {:.2} ms @100MHz, predicted class {}",
        fmt_cycles(total_v3),
        total_v3 as f64 / 100e6 * 1e3,
        out_v3.class
    );

    // --- Golden cross-check: PJRT backbone artifact (skipped when the
    // runtime or the artifacts are unavailable on an offline checkout). ---
    match Runtime::cpu() {
        Ok(rt) => {
            let exe = rt.load_hlo(
                &artifact_path("backbone.hlo.txt")?,
                (c0.h * c0.w * c0.cin) as usize,
            )?;
            let golden = infer_golden(&exe, &x)?;
            anyhow::ensure!(golden.logits == out_v3.logits, "logits mismatch vs golden model");
            println!("logits bit-exact vs PJRT backbone golden model ✓ ({:?})", golden.logits);
        }
        Err(e) => println!("PJRT golden cross-check skipped: {e}"),
    }

    // --- Baseline comparison (software-only, whole network). ---
    println!("\nrunning the software baseline over the whole network (~250M simulated cycles)...");
    let sw = Engine::new(params, Backend::SoftwareIss).infer(&x)?;
    anyhow::ensure!(sw.logits == out_v3.logits, "baseline logits mismatch");
    println!(
        "software total: {} cycles = {:.1} ms @100MHz",
        fmt_cycles(sw.sim_cycles),
        sw.sim_cycles as f64 / 100e6 * 1e3
    );
    println!(
        "END-TO-END SPEEDUP (full network): {:.1}x   (paper reports up to 59.3x per layer)",
        sw.sim_cycles as f64 / total_v3 as f64
    );
    Ok(())
}
